"""Checkpointer backend registry: name -> factory.

Backends self-register at import via the `@register_backend` decorator;
`create_checkpointer` is the single construction path every driver uses
(directly or through `CheckpointSpec.build` / `CheckpointSession`).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

from repro.api.types import Checkpointer, CheckpointSpec

_REGISTRY: Dict[str, Callable[[CheckpointSpec, Any], Checkpointer]] = {}


def register_backend(name: str):
    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = factory
        return factory
    return deco


def available_backends() -> list:
    _load_builtin()
    return sorted(_REGISTRY)


def create_checkpointer(spec: CheckpointSpec,
                        state_template: Any) -> Checkpointer:
    _load_builtin()
    try:
        factory = _REGISTRY[spec.backend]
    except KeyError:
        raise KeyError(f"unknown checkpointer backend {spec.backend!r}; "
                       f"available: {available_backends()}") from None
    return factory(spec, state_template)


def _load_builtin():
    # import for registration side effects (idempotent)
    from repro.api import backends as _b          # noqa: F401
    from repro.api import disk as _d              # noqa: F401
    from repro.api import objstore as _o          # noqa: F401
