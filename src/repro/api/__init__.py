"""repro.api — the unified checkpointing facade.

    from repro.api import CheckpointSpec, CheckpointSession

    spec = CheckpointSpec(backend="reft", ckpt_dir="/tmp/run", sg_size=4)
    with CheckpointSession(spec, state_template) as sess:
        ...
        sess.after_step(state, step, extra_meta=ds.state())

Backends: reft | sync_disk | async_disk | null (see docs/API.md).
"""
from repro.api.registry import (
    available_backends, create_checkpointer, register_backend,
)
from repro.api.session import CheckpointSession
from repro.api.types import (
    Checkpointer, CheckpointSpec, CkptEvent, RestoreResult, RestoreTarget,
)

__all__ = [
    "Checkpointer", "CheckpointSpec", "CheckpointSession", "CkptEvent",
    "RestoreResult", "RestoreTarget", "available_backends",
    "create_checkpointer", "register_backend",
]
