"""Disk checkpointing backends (paper §6.1 baselines, unified API).

Low-level machinery (one on-disk format, phase-timed):
  * `DiskWriter` — d2h copy + byte-stream framing + (optionally sharded,
    parallel) file I/O, run synchronously or overlapped on a thread.
  * `load_checkpoint` / `latest_complete_step` — reassembly + discovery.

Facade backends registered here:
  * `sync_disk`  — blocking full-state save each snapshot() (the classic
    torch.save-style baseline; worst overhead, simplest semantics).
  * `async_disk` — overlapped save (CheckFreq-style unsharded by default;
    `options={"shard": True}` gives the TorchSnapshot-style 1/m-per-rank
    variant with parallel I/O).

The legacy class names (`CheckFreqCheckpointer`, `TorchSnapshotCheckpointer`)
survive as thin aliases in `repro.ckpt`.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.api.registry import register_backend
from repro.api.types import Checkpointer, CheckpointSpec, RestoreResult
from repro.core.recovery import RecoveryError
from repro.core.snapshot import _LeafReader
from repro.core.treebytes import (
    FlatSpec, buffer_to_tree, leaf_arrays, make_flat_spec,
)


@dataclass
class PhaseTimes:
    d2h: float = 0.0
    serialize: float = 0.0
    persist: float = 0.0
    total: float = 0.0


class DiskWriter:
    """Common save machinery; `shard=False` -> CheckFreq, True ->
    TorchSnapshot (state split along DP paths, parallel per-rank I/O)."""

    name = "disk"

    def __init__(self, out_dir: str, state_template: Any, *,
                 n_ranks: int = 1, shard: bool = False,
                 bucket_bytes: int = 16 << 20, fsync: bool = False):
        self.dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.spec = make_flat_spec(state_template)
        self.n_ranks = n_ranks
        self.shard = shard
        self.bucket_bytes = bucket_bytes
        self.fsync = fsync
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self.last_times = PhaseTimes()
        self.last_step = -1

    # ------------------------------------------------------------ ranges
    def _rank_range(self, rank: int) -> Tuple[int, int]:
        total = self.spec.total_bytes
        if not self.shard:
            return 0, total
        per = -(-total // self.n_ranks)
        return min(rank * per, total), min((rank + 1) * per, total)

    # -------------------------------------------------------------- save
    def save_async(self, state: Any, step: int,
                   extra_meta: dict = None) -> bool:
        if self._thread is not None and self._thread.is_alive():
            return False                      # previous ckpt still in flight
        self._raise_pending()
        leaves = leaf_arrays(state)
        self._thread = threading.Thread(
            target=self._run, args=(leaves, int(step), extra_meta or {}),
            daemon=True)
        self._thread.start()
        return True

    def save_sync(self, state: Any, step: int,
                  extra_meta: dict = None) -> PhaseTimes:
        assert self.save_async(state, step, extra_meta)
        self.wait()
        return self.last_times

    def wait(self, timeout: float = 600.0):
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _run(self, leaves, step, extra_meta):
        try:
            t_all = time.time()
            times = PhaseTimes()
            # phase 1: d2h ("snapshotting") of every rank's range
            t0 = time.time()
            reader = _LeafReader(self.spec, leaves)
            bufs: Dict[int, np.ndarray] = {}
            for r in range(self.n_ranks):
                lo, hi = self._rank_range(r)
                buf = np.empty(hi - lo, np.uint8)
                reader.read(lo, hi, buf)
                bufs[r] = buf
                if not self.shard:
                    break                      # every rank copies the same
            times.d2h = time.time() - t0

            # phase 2: serialization (byte-stream framing, paper step 2)
            t0 = time.time()
            blobs: Dict[int, bytes] = {}
            for r, buf in bufs.items():
                lo, hi = self._rank_range(r)
                head = {"step": step, "rank": r, "lo": lo, "hi": hi,
                        "n_ranks": self.n_ranks if self.shard else 1,
                        "spec": self.spec.to_json(), "extra": extra_meta}
                blobs[r] = pickle.dumps(head) + buf.tobytes()
            times.serialize = time.time() - t0

            # phase 3: persist (parallel I/O for the sharded variant)
            t0 = time.time()
            threads = []
            for r, blob in blobs.items():
                th = threading.Thread(target=self._write, args=(step, r, blob))
                th.start()
                threads.append(th)
            for th in threads:
                th.join()
            times.persist = time.time() - t0
            times.total = time.time() - t_all
            self.last_times = times
            self.last_step = step
        except BaseException as e:
            self._err = e

    def _write(self, step, rank, blob):
        path = os.path.join(self.dir, f"ckpt-{step}-r{rank}.bin")
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                f.write(blob)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            try:
                os.unlink(tmp)             # no-op after a clean replace
            except FileNotFoundError:
                pass


# ------------------------------------------------------------------ load
def _shard_files(out_dir: str, step: int) -> list:
    return sorted(f for f in os.listdir(out_dir)
                  if f.startswith(f"ckpt-{step}-r") and f.endswith(".bin"))


def latest_complete_step(out_dir: str) -> Optional[int]:
    """Newest step whose shard family is fully on disk."""
    steps: Dict[int, int] = {}
    try:
        names = os.listdir(out_dir)
    except FileNotFoundError:
        return None
    for fn in names:
        if fn.startswith("ckpt-") and fn.endswith(".bin"):
            try:
                steps[int(fn.split("-")[1])] = steps.get(
                    int(fn.split("-")[1]), 0) + 1
            except ValueError:
                continue
    for step in sorted(steps, reverse=True):
        fn = _shard_files(out_dir, step)[0]
        with open(os.path.join(out_dir, fn), "rb") as f:
            head = pickle.load(f)
        if steps[step] >= head["n_ranks"]:
            return step
    return None


def load_checkpoint(out_dir: str, step: int, template: Any,
                    with_meta: bool = False):
    """Reassemble a checkpoint written by any disk backend."""
    files = _shard_files(out_dir, step)
    if not files:
        raise FileNotFoundError(f"no checkpoint for step {step} in {out_dir}")
    buf = None
    spec = None
    extra: dict = {}
    for fn in files:
        with open(os.path.join(out_dir, fn), "rb") as f:
            head = pickle.load(f)
            payload = np.frombuffer(f.read(), np.uint8)
        spec = FlatSpec.from_json(head["spec"])
        extra = head.get("extra", {})
        if buf is None:
            buf = np.zeros(spec.total_bytes, np.uint8)
        buf[head["lo"]:head["hi"]] = payload[:head["hi"] - head["lo"]]
        if head["n_ranks"] == 1:
            break
    tree = buffer_to_tree(template, spec, buf)
    return (tree, extra) if with_meta else tree


# ----------------------------------------------------------- facade glue
class _DiskCheckpointer(Checkpointer):
    """Checkpointer protocol over `DiskWriter`."""

    def __init__(self, spec: CheckpointSpec, state_template: Any, *,
                 sync: bool):
        super().__init__(spec)
        self.sync = sync
        self.template = state_template
        shard = bool(spec.options.get("shard", False))
        self.writer = DiskWriter(
            spec.ckpt_dir, state_template,
            n_ranks=spec.sg_size if shard else 1, shard=shard,
            bucket_bytes=spec.options.get(
                "io_bucket_bytes", max(spec.bucket_bytes, 16 << 20)),
            fsync=spec.fsync)

    def snapshot(self, state, step, extra_meta=None, wait=False):
        t0 = time.perf_counter()
        if self.sync or wait:
            self.writer.wait()                 # drain any in-flight save
            times = self.writer.save_sync(state, step, extra_meta)
            self.emit("snapshot", step, seconds=times.total,
                      nbytes=self.writer.spec.total_bytes)
            return True
        started = self.writer.save_async(state, step, extra_meta)
        if started:
            self.emit("snapshot", step, seconds=time.perf_counter() - t0,
                      nbytes=self.writer.spec.total_bytes,
                      detail="async-launch")
        return started

    def persist(self, step=None, wait=True):
        """Disk saves are already durable once the writer finishes; the
        drain IS the durability barrier, so `wait` is accepted for
        protocol parity and ignored (the types.py contract for
        inherently synchronous persists) — `async_disk`'s overlap is the
        save itself, and skipping the drain would return un-durable
        steps as tickets no poll ever completes."""
        t0 = time.perf_counter()
        self.writer.wait()
        last = self.writer.last_step
        if last >= 0:
            self.emit("persist", last, seconds=time.perf_counter() - t0)
            self._gc(keep_from=last)
        return last if last >= 0 else None

    def _gc(self, keep_from: int):
        """Keep-latest-k over COMPLETE families; torn families (a crash
        mid-save) are garbage outright — _gc only runs after wait(), so
        nothing here can be in flight.  Counting torn families toward
        `keep` would let every crash evict a restorable checkpoint."""
        from repro.ckpt.manager import plan_gc
        keep = self.spec.keep
        if not keep:
            return
        expect = self.writer.n_ranks if self.writer.shard else 1
        families: Dict[int, list] = {}
        for fn in os.listdir(self.writer.dir):
            if fn.startswith("ckpt-") and fn.endswith(".bin"):
                families.setdefault(int(fn.split("-")[1]), []).append(fn)
        complete = {s for s, fns in families.items() if len(fns) >= expect}
        kept = set(sorted(complete)[-keep:])
        removed = 0
        for s in plan_gc(families, complete, kept):
            for fn in families[s]:
                try:
                    os.remove(os.path.join(self.writer.dir, fn))
                    removed += 1
                except FileNotFoundError:
                    pass
        if removed:
            self.emit("gc", keep_from, detail=f"removed {removed} shards")

    def restore(self, step=None, target=None):
        from repro.core.loader import LoadStats
        t0 = time.perf_counter()
        self.writer.wait()
        step = latest_complete_step(self.writer.dir) if step is None else step
        if step is None:
            raise RecoveryError(f"no disk checkpoint in {self.writer.dir}")
        state, extra = load_checkpoint(self.writer.dir, step, self.template,
                                       with_meta=True)
        # disk baselines read shard files whole (that inefficiency is the
        # paper's point of comparison) — report honest monolithic stats
        st = LoadStats(tier="disk", source="file",
                       bytes_read=self.writer.spec.total_bytes,
                       bytes_needed=self.writer.spec.total_bytes,
                       read_seconds=time.perf_counter() - t0)
        st.wall_seconds = st.read_seconds
        self.emit("restore", step, seconds=time.perf_counter() - t0,
                  tier="disk")
        return RestoreResult(state=state, step=step, extra_meta=extra,
                             tier="disk", load=st)

    def health(self):
        inflight = (self.writer._thread is not None
                    and self.writer._thread.is_alive())
        return {"healthy": True, "degraded": [],
                "members": {"inflight": inflight,
                            "last_step": self.writer.last_step}}

    def wait(self):
        self.writer.wait()

    def close(self):
        try:
            self.writer.wait(timeout=30)
        except BaseException:
            pass


@register_backend("sync_disk")
def _make_sync(spec: CheckpointSpec, template: Any) -> Checkpointer:
    ck = _DiskCheckpointer(spec, template, sync=True)
    ck.name = "sync_disk"
    return ck


@register_backend("async_disk")
def _make_async(spec: CheckpointSpec, template: Any) -> Checkpointer:
    ck = _DiskCheckpointer(spec, template, sync=False)
    ck.name = "async_disk"
    return ck
