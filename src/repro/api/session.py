"""CheckpointSession — the lifecycle object training loops actually hold.

Owns everything the drivers used to hand-wire individually:
  * run-id allocation (one id per session unless the spec pins one);
  * snapshot / checkpoint cadence in steps, including the Appendix-A
    adaptive policy (`auto_tune=True` re-derives the optimal snapshot
    interval from measured per-step compute and per-snapshot saving time,
    subsuming the old inline `FrequencyPlan` wiring);
  * degraded-mode handling — a lost fault-tolerance sidecar must never
    kill training: degradation is surfaced as events + `health()`, and the
    loop keeps running;
  * restore-on-entry — `with CheckpointSession(...) as sess:` resumes from
    whatever the backend can reconstruct (`sess.restored`), so a relaunched
    job continues instead of restarting;
  * a final drain + persist on clean exit.

Typical loop:

    spec = CheckpointSpec(backend="reft", ckpt_dir=..., sg_size=4)
    with CheckpointSession(spec, state_template) as sess:
        if sess.restored:
            state, step = sess.restored.state, sess.restored.step
        while step < total:
            state, metrics = train_step(state, batch)
            sess.after_step(state, step, extra_meta=ds.state())
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional, Sequence

from repro.api.types import (
    Checkpointer, CheckpointSpec, CkptEvent, RestoreResult, RestoreTarget,
)
from repro.core.pipeline import step_boundary
from repro.core.recovery import RecoveryError


class CheckpointSession:
    def __init__(self, spec: CheckpointSpec, state_template: Any, *,
                 on_event: Optional[Callable[[CkptEvent], None]] = None,
                 restore_target: Optional[RestoreTarget] = None,
                 observer: Optional[Any] = None):
        if spec.run_id is None:
            spec = spec.with_run_id(CheckpointSpec.alloc_run_id())
        self.spec = spec
        # MTBF + restore-cost feedback into the Appendix-A tuner: pass a
        # shared FailureObserver to carry observations across elastic
        # session rebuilds (the supervisor does); default is per-session
        if observer is None:
            from repro.core.policy import FailureObserver
            observer = FailureObserver()
        self.observer = observer
        self.run_id = spec.run_id
        self.checkpointer: Checkpointer = spec.build(state_template)
        self.checkpointer.on_event = on_event
        # hand the observer to the backend so restores can seed the read
        # scheduler's bandwidth priors from cross-restore history
        self.checkpointer.observer = observer
        # restore-on-entry (and every sess.restore()) declares the CURRENT
        # layout so a checkpoint saved under a different sg_size/mesh is
        # resharded by the distributed loader (elastic n->m restart)
        self.restore_target = restore_target or RestoreTarget(
            sg_size=spec.sg_size,
            device_put=bool(spec.options.get("restore_device_put", False)))
        self.restored: Optional[RestoreResult] = None
        self.snapshot_every = max(1, spec.snapshot_every_steps)
        self.checkpoint_every = max(1, spec.checkpoint_every_steps)
        self._last_snapshot = -1
        self._last_persist = -1
        self._last_call_t: Optional[float] = None
        self._step_times: List[float] = []
        self._degraded_seen: set = set()
        # cadence persists fire WITHOUT blocking on disk I/O when the
        # backend supports it (persist(wait=False) tickets); completion
        # is polled alongside snapshot flights in after_step.
        # options["persist_blocking"] forces the old inline behavior.
        self._persist_kwargs: dict = {}
        if not spec.options.get("persist_blocking", False):
            import inspect
            try:
                params = inspect.signature(
                    self.checkpointer.persist).parameters
            except (TypeError, ValueError):
                params = {}
            if "wait" in params:
                self._persist_kwargs = {"wait": False}

    # ----------------------------------------------------------- entry
    def _restore_call(self, step, target) -> RestoreResult:
        import inspect
        try:
            params = inspect.signature(self.checkpointer.restore).parameters
        except (TypeError, ValueError):
            params = {}
        if "target" in params:     # third-party backends may predate it
            return self.checkpointer.restore(step, target=target)
        return self.checkpointer.restore(step)

    def __enter__(self) -> "CheckpointSession":
        if self.spec.resume:
            try:
                self.restored = self._restore_call(None, self.restore_target)
            except (RecoveryError, FileNotFoundError):
                self.restored = None
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(final_persist=exc_type is None)
        return False

    def close(self, final_persist: bool = True):
        try:
            if final_persist:
                try:
                    self.checkpointer.wait()
                    if self._last_snapshot >= 0:
                        self.checkpointer.persist()
                except Exception as e:
                    # fault tolerance must not crash a finished run, but a
                    # failed FINAL persist means the newest durable state
                    # is stale — say so loudly instead of exiting silent
                    import sys
                    print(f"[repro.api] WARNING: final persist failed: "
                          f"{type(e).__name__}: {e}", file=sys.stderr)
        finally:
            self.checkpointer.close()

    # --------------------------------------------------------- cadence
    def after_step(self, state: Any, step: int,
                   extra_meta: dict = None) -> dict:
        """Call once per training step; runs whatever is due.  Returns
        {"snapshot": bool, "persist": Optional[int]}."""
        # tick the HASC gate: in-flight L1 pumps burst at step boundaries
        # instead of racing the forward/backward pass for host bandwidth
        step_boundary()
        now = time.time()
        if self._last_call_t is not None:
            self._step_times.append(now - self._last_call_t)
        self._last_call_t = now
        if self.spec.auto_tune:
            self._retune()

        did = {"snapshot": False, "persist": None}
        if step - self._last_snapshot >= self.snapshot_every:
            if self.checkpointer.snapshot(state, step, extra_meta):
                self._last_snapshot = step
                did["snapshot"] = True
        if step - self._last_persist >= self.checkpoint_every:
            # fire-and-overlap: the SMPs stream their shards to disk in
            # the background; after_step returns without touching disk
            did["persist"] = self.checkpointer.persist(
                **self._persist_kwargs)
            self._last_persist = step
        # collect async persists that completed since the last step (the
        # backend emits their `persist` events / commits the manifest)
        self.checkpointer.poll_persists()
        self._watch_degraded(step)
        return did

    def _retune(self):
        """Appendix A (Eqs. 8-11): effective overhead -> optimal intervals,
        converted to steps with the measured compute time."""
        from repro.core.policy import plan_frequencies
        warmup = 4
        if len(self._step_times) < warmup:
            return
        st = self.checkpointer.stats()
        # prefer engine-side timing: with async launches the trainer-side
        # snapshot_seconds is just the (near-zero) thread-start cost, which
        # would make the tuner conclude snapshots are free
        n_snap = st.get("engine_snapshots") or st.get("snapshot", 0)
        if not n_snap:
            return
        t_comp = sum(self._step_times[-warmup:]) / warmup
        t_sn = st.get("engine_seconds",
                      st.get("snapshot_seconds", 0.0)) / n_snap
        t_ck = (st.get("persist_seconds", 0.0) / st["persist"]
                if st.get("persist") else t_sn)
        # closed loop: observed failures move lam off the static prior
        # (Gamma posterior), and observed per-tier restore costs inflate
        # the effective rate — a failure-heavy run snapshots more often,
        # a quiet one relaxes back toward the prior-derived cadence
        lam = self.observer.lam_node(prior=self.spec.lam_node,
                                     n=self.spec.sg_size)
        plan = plan_frequencies(
            t_snapshot=t_sn, t_checkpoint=t_ck,
            t_comp=t_comp, lam_node=lam, n=self.spec.sg_size,
            t_restore_snapshot=self.observer.restore_cost("snapshot"),
            t_restore_checkpoint=self.observer.restore_cost("checkpoint"))
        self.snapshot_every = max(
            1, int(plan.snapshot_interval / max(t_comp, 1e-9)))
        if plan.checkpoint_interval != float("inf"):
            self.checkpoint_every = max(
                self.snapshot_every,
                int(plan.checkpoint_interval / max(t_comp, 1e-9)))

    def _watch_degraded(self, step):
        h = self.checkpointer.health()
        for node in h["degraded"]:
            if node not in self._degraded_seen:
                self._degraded_seen.add(node)

    # ------------------------------------------------ recovery surface
    def restore(self, step: Optional[int] = None,
                target: Optional[RestoreTarget] = None) -> RestoreResult:
        """Run the backend's recovery ladder and heal failed members so
        training can continue with full protection.  `target` overrides
        the session's restore target for this one call (partial loads,
        explicit reshard)."""
        t0 = time.monotonic()
        res = self._restore_call(step, target or self.restore_target)
        self.observer.record_restore(time.monotonic() - t0,
                                     tier=res.tier, load=res.load)
        self.checkpointer.heal()
        self._degraded_seen.clear()
        return res

    def inject(self, kind: str, node: int = 0, graceful: bool = True,
               **params):
        """Simulate a failure.  `graceful=True` (the historical behavior)
        drains in-flight saves first, so the fault lands at a quiesced
        step boundary; `graceful=False` injects MID-FLIGHT — whatever
        snapshots/persists are in the air stay in the air, which is what
        real failures look like.  Kind-specific `params` (grace_s, lag_s,
        delay_s, nbytes, seed) pass through to the backend."""
        if graceful:
            self.checkpointer.wait()
        self.checkpointer.inject_failure(node, kind, **params)
        from repro.supervise.inject import FAILURE_KINDS
        if kind in FAILURE_KINDS:      # perf faults aren't MTBF arrivals
            self.observer.record_failure()

    # ------------------------------------------------------ passthrough
    def snapshot(self, state, step, extra_meta=None, wait=False):
        ok = self.checkpointer.snapshot(state, step, extra_meta, wait=wait)
        if ok:
            self._last_snapshot = step
        return ok

    def persist(self, step=None, wait=True):
        # a manual persist resets the cadence clock too (a persist right
        # before a cadence boundary should not be repeated at it)
        self._last_persist = step if step is not None else self._last_snapshot
        if not wait and self._persist_kwargs:
            return self.checkpointer.persist(step, wait=False)
        return self.checkpointer.persist(step)

    def wait(self):
        self.checkpointer.wait()

    def drain(self):
        """Join ALL outstanding async work — in-flight snapshots and
        fired-but-unfinished persists — and collect their events."""
        self.checkpointer.wait()
        self.checkpointer.poll_persists()

    def health(self) -> dict:
        return self.checkpointer.health()

    def stats(self) -> dict:
        return self.checkpointer.stats()

    @property
    def events(self) -> Sequence[CkptEvent]:
        return self.checkpointer.events

    @property
    def degraded(self) -> bool:
        return bool(self._degraded_seen)
