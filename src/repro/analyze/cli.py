"""`python -m repro.analyze [--strict] [--json OUT] PATH...`

The CI gate: runs the AST lint rules over the given trees, the bounded
SMP protocol model check, and a static census of lock creation sites
(how many `threading` primitives still bypass the named-lock factories).
``--strict`` exits 1 on any unsuppressed lint finding, any model-checker
violation/wedge, or an incomplete state-space exploration.  ``--json``
writes the findings summary CI uploads as ``BENCH_analyze.json``; pass
``--lockgraph FILE`` to merge a pytest lockgraph dump (see
tests/conftest.py) into that summary.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import List

from repro.analyze.lint import RULES, Finding, iter_py, lint_file
from repro.analyze.protocol import CheckConfig, model_check

__all__ = ["main"]


def _lock_census(paths: List[Path]) -> dict:
    """Count lock creation sites: named (via the lockgraph factories) vs
    raw `threading.Lock/RLock/Condition()` calls."""
    named = raw = 0
    raw_sites: List[str] = []
    for root in paths:
        for p in iter_py(Path(root)):
            try:
                tree = ast.parse(p.read_text(encoding="utf-8"))
            except SyntaxError:
                continue
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                name = ""
                if isinstance(fn, ast.Attribute):
                    base = fn.value
                    if isinstance(base, ast.Name) and base.id == "threading":
                        name = fn.attr
                elif isinstance(fn, ast.Name):
                    name = fn.id
                if name in ("named_lock", "named_rlock", "named_condition"):
                    named += 1
                elif (isinstance(fn, ast.Attribute)
                      and name in ("Lock", "RLock", "Condition")):
                    raw += 1
                    raw_sites.append(f"{p}:{node.lineno}")
    return {"named": named, "raw": raw, "raw_sites": raw_sites}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analyze")
    ap.add_argument("paths", nargs="+", help="files or trees to lint")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on findings / model-check violations")
    ap.add_argument("--json", help="write summary JSON here")
    ap.add_argument("--lockgraph",
                    help="merge a lockgraph dump (from the pytest plugin)")
    ap.add_argument("--no-model-check", action="store_true",
                    help="lint only (skip the SMP protocol model check)")
    ap.add_argument("--snapshots", type=int, default=2,
                    help="model-check bound: snapshot flights")
    ap.add_argument("--persists", type=int, default=2,
                    help="model-check bound: in-flight persists")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in args.paths]
    suppressed: List[Finding] = []
    findings: List[Finding] = []
    for root in paths:
        for p in iter_py(root):
            findings.extend(lint_file(p, suppressed))

    for f in findings:
        print(f, file=sys.stderr)

    rule_counts = {r: 0 for r in RULES}
    for f in findings:
        rule_counts[f.rule] = rule_counts.get(f.rule, 0) + 1
    sup_counts: dict = {}
    for f in suppressed:
        sup_counts[f.rule] = sup_counts.get(f.rule, 0) + 1

    summary = {
        "findings": len(findings),
        "suppressed": len(suppressed),
        "rule_counts": rule_counts,
        "suppressed_counts": sup_counts,
        "locks": _lock_census(paths),
    }

    mc_bad = False
    if not args.no_model_check:
        res = model_check(CheckConfig(max_snapshots=args.snapshots,
                                      max_persists=args.persists))
        summary["model_check"] = {
            "states": res.states,
            "transitions": res.transitions,
            "violations": len(res.violations),
            "wedges": len(res.wedges),
            "complete": res.complete,
        }
        mc_bad = not res.ok
        print(f"model check: {res.states} states, {res.transitions} "
              f"transitions, {len(res.violations)} violations, "
              f"{len(res.wedges)} wedges, complete={res.complete}",
              file=sys.stderr)
        for v in (res.violations + res.wedges)[:5]:
            print(f"  counterexample: {v.get('kind', 'wedge')}\n"
                  f"    trace: {' '.join(v['trace'])}", file=sys.stderr)

    if args.lockgraph:
        try:
            summary["lockgraph"] = json.loads(
                Path(args.lockgraph).read_text())
        except (OSError, ValueError) as e:
            print(f"lockgraph merge failed: {e}", file=sys.stderr)

    if args.json:
        Path(args.json).write_text(json.dumps(summary, indent=2,
                                              sort_keys=True))

    print(f"analyze: {len(findings)} findings "
          f"({len(suppressed)} pragma-suppressed)", file=sys.stderr)
    if args.strict and (findings or mc_bad):
        return 1
    return 0
