"""Runtime lock-order checker (repro.analyze part 2).

Concurrency bugs in this repo historically live where many actors
interleave (HASC levels, the SMP persist worker, the read scheduler's
stealing pool).  This module makes the *lock discipline* of those actors
machine-checked: every lock the saving/restore paths create goes through
`named_lock`/`named_rlock`/`named_condition`, which return plain
`threading` primitives when tracing is off (zero overhead) and
instrumented wrappers when a `LockTracer` is installed.

The tracer records, per thread, the stack of named locks currently held;
each acquisition of lock B while A is held adds the edge A -> B to a
global lock-order graph.  Two failure modes are reported:

  * inconsistent order — both A -> B and B -> A observed (the classic
    ABBA deadlock precondition), detected eagerly at the second
    acquisition with sample stacks for BOTH directions;
  * cycles — any longer cycle in the accumulated order graph, found by
    `check()` / `cycles()` at report time.

Edges are keyed by lock *name* (a stable role string like
``"smp.handle.tx"``), not instance, so the discipline generalises across
members and runs; self-edges (two instances of the same role, or RLock
re-entry) are recorded separately and are not violations by default.

The pytest plugin in ``tests/conftest.py`` installs a tracer for the
whole tier-1 run when ``ANALYZE_LOCKGRAPH=1`` (CI does), failing any
test that introduces a violation and dumping the discovered graph to
``ANALYZE_LOCKGRAPH_JSON`` at session end — the tier-1 suite doubles as
the dynamic corpus across pipeline, smp, readsched and supervise.
"""
from __future__ import annotations

import threading
import traceback
from typing import Dict, List, Optional, Set, Tuple

__all__ = [
    "LockOrderViolation", "LockTracer", "TracedLock", "TracedCondition",
    "named_lock", "named_rlock", "named_condition", "install", "uninstall",
    "current_tracer",
]


class LockOrderViolation(RuntimeError):
    """An ABBA pair or cycle in the observed lock-order graph."""


def _stack(skip: int = 3) -> str:
    return "".join(traceback.format_stack()[:-skip][-6:])


class LockTracer:
    """Global lock-order graph + per-thread held stacks."""

    def __init__(self, keep_stacks: bool = True):
        self._mu = threading.Lock()           # guards graph bookkeeping
        self._tls = threading.local()
        self.keep_stacks = keep_stacks
        # name -> set of names acquired while `name` was held
        self.edges: Dict[str, Set[str]] = {}
        self.edge_stacks: Dict[Tuple[str, str], str] = {}
        self.locks_seen: Set[str] = set()
        self.self_edges: Set[str] = set()
        self.acquisitions = 0
        self.violations: List[dict] = []

    # ------------------------------------------------------- held stack
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def push(self, name: str) -> None:
        held = self._held()
        with self._mu:
            self.acquisitions += 1
            self.locks_seen.add(name)
            for h in held:
                if h == name:
                    self.self_edges.add(name)
                    continue
                fresh = name not in self.edges.get(h, ())
                self.edges.setdefault(h, set()).add(name)
                if fresh and self.keep_stacks:
                    self.edge_stacks[(h, name)] = _stack()
                # eager ABBA: the reverse edge already exists
                if fresh and h in self.edges.get(name, ()):
                    self.violations.append({
                        "kind": "inconsistent-order",
                        "pair": (h, name),
                        "stack_forward": self.edge_stacks.get((h, name), ""),
                        "stack_reverse": self.edge_stacks.get((name, h), ""),
                    })
        held.append(name)

    def pop(self, name: str) -> None:
        held = self._held()
        # locks are not always released LIFO (e.g. Condition.wait): drop
        # the newest matching entry
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    # -------------------------------------------------------- reporting
    def cycles(self) -> List[List[str]]:
        """All elementary cycles reachable in the order graph (DFS)."""
        with self._mu:
            graph = {k: sorted(v) for k, v in self.edges.items()}
        out: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()
        color: Dict[str, int] = {}
        path: List[str] = []

        def dfs(u: str) -> None:
            color[u] = 1
            path.append(u)
            for v in graph.get(u, ()):
                if color.get(v, 0) == 1:
                    cyc = path[path.index(v):] + [v]
                    key = tuple(sorted(set(cyc)))
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        out.append(cyc)
                elif color.get(v, 0) == 0:
                    dfs(v)
            path.pop()
            color[u] = 2

        for node in sorted(graph):
            if color.get(node, 0) == 0:
                dfs(node)
        return out

    def check(self) -> None:
        """Raise `LockOrderViolation` on any ABBA pair or cycle."""
        cycs = self.cycles()
        if self.violations or cycs:
            lines = [f"inconsistent order {v['pair'][0]} <-> {v['pair'][1]}"
                     for v in self.violations]
            lines += [" -> ".join(c) for c in cycs]
            raise LockOrderViolation(
                "lock-order violations:\n  " + "\n  ".join(lines))

    def summary(self) -> dict:
        # cycles() takes _mu itself — compute before entering the region
        cycs = [list(c) for c in self.cycles()]
        with self._mu:
            return {
                "locks": sorted(self.locks_seen),
                "edges": sorted((a, b) for a, bs in self.edges.items()
                                for b in bs),
                "self_edges": sorted(self.self_edges),
                "acquisitions": self.acquisitions,
                "violations": [
                    {"kind": v["kind"], "pair": list(v["pair"])}
                    for v in self.violations],
                "cycles": cycs,
            }


class TracedLock:
    """`threading.Lock`/`RLock` wrapper feeding a `LockTracer`."""

    def __init__(self, name: str, tracer: LockTracer, rlock: bool = False):
        self.name = name
        self._tracer = tracer
        self._inner = threading.RLock() if rlock else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._tracer.push(self.name)
        return ok

    def release(self) -> None:
        self._tracer.pop(self.name)
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class TracedCondition:
    """`threading.Condition` wrapper: `wait` releases the underlying lock,
    so the held record is popped for the duration of the wait — a thread
    blocked in `cond.wait()` holds nothing and must not contribute order
    edges for its wakeup reacquisition's sake."""

    def __init__(self, name: str, tracer: LockTracer):
        self.name = name
        self._tracer = tracer
        self._inner = threading.Condition()

    def acquire(self, *a, **kw) -> bool:
        ok = self._inner.acquire(*a, **kw)
        if ok:
            self._tracer.push(self.name)
        return ok

    def release(self) -> None:
        self._tracer.pop(self.name)
        self._inner.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        self._tracer.pop(self.name)
        try:
            return self._inner.wait(timeout)
        finally:
            self._tracer.push(self.name)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        self._tracer.pop(self.name)
        try:
            return self._inner.wait_for(predicate, timeout)
        finally:
            self._tracer.push(self.name)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


# ------------------------------------------------------------- factories
_TRACER: Optional[LockTracer] = None


def install(tracer: Optional[LockTracer] = None) -> LockTracer:
    """Install (and return) the process-global tracer.  Locks created
    BEFORE install stay plain — install early (the pytest plugin does it
    at configure time, before any repro module builds a lock)."""
    global _TRACER
    _TRACER = tracer or LockTracer()
    return _TRACER


def uninstall() -> None:
    global _TRACER
    _TRACER = None


def current_tracer() -> Optional[LockTracer]:
    return _TRACER


def named_lock(name: str):
    """A `threading.Lock` under `name` in the lock-order graph; a plain
    lock (zero overhead) when no tracer is installed."""
    if _TRACER is None:
        return threading.Lock()
    return TracedLock(name, _TRACER)


def named_rlock(name: str):
    if _TRACER is None:
        return threading.RLock()
    return TracedLock(name, _TRACER, rlock=True)


def named_condition(name: str):
    if _TRACER is None:
        return threading.Condition()
    return TracedCondition(name, _TRACER)
