"""Repo-specific AST lint rules (repro.analyze part 1).

These are not style checks — each rule encodes a bug class this codebase
has actually shipped (see CHANGES.md) or a discipline the concurrency
design depends on:

  ANZ001  mutable default argument / dataclass field.  A shared
          ``ReftConfig()`` default aliased config across checkpointers
          in PR 1; any list/dict/set display, ``dict()``-style call or
          CamelCase constructor call in a parameter default or a
          non-``field(default_factory=...)`` dataclass field is flagged.
  ANZ002  blocking call while a lock is statically held: ``time.sleep``,
          thread ``.join()``, pipe ``.recv()``, ``open()``/``os.fsync``
          lexically inside a ``with <lock-like>:`` body stalls every
          other actor contending that lock.  (``Condition.wait`` is
          exempt — it releases.)
  ANZ003  pipe send outside the owning tx-lock: ``conn.send`` from two
          threads interleaves pickled frames; every send must sit inside
          a ``with <lock>:`` (the SMP's demux depends on it).
  ANZ004  temp-file write without a ``finally`` unlink: a ``tmp``-named
          path opened outside a try/finally that unlinks it leaks the
          partial file on error (PR 5's tmp-file leak).
  ANZ005  bare ``except:`` — swallows KeyboardInterrupt/SystemExit.
  ANZ006  nondeterminism in a seeded planner: wall-clock/uuid/global-RNG
          calls inside ``plan_*`` functions break replayable failure
          schedules (``inject.plan_scenarios`` must be seed-pure).
  ANZ007  ``time.sleep`` inside a ``while`` loop — a polling loop; use
          events/conditions, or justify with a pragma.

Suppression: append ``# analyze: ok RULE-ID[, RULE-ID...]`` to the
finding line (or the line directly above).  Pragmas are deliberate,
reviewable allowlists — each one should say why in the surrounding code.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

__all__ = ["Finding", "RULES", "lint_source", "lint_file", "lint_paths",
           "iter_py"]

RULES: Dict[str, str] = {
    "ANZ001": "mutable default argument / dataclass field",
    "ANZ002": "blocking call while a lock is held",
    "ANZ003": "pipe send outside the owning tx-lock",
    "ANZ004": "temp-file write without a finally unlink",
    "ANZ005": "bare except",
    "ANZ006": "nondeterminism in a seeded planner",
    "ANZ007": "time.sleep polling loop",
}

_PRAGMA = re.compile(r"#\s*analyze:\s*ok\s+([A-Z0-9*,\s]+)")
_LOCKY = re.compile(r"(lock|mutex|cond|guard|sem4lock|^_?mu$)", re.I)
_PIPEY = re.compile(r"(^|_)(conn|pipe|child|sock)$")
_TMPY = re.compile(r"(^|[._])tmp", re.I)
# wall-clock / entropy calls that break seeded replay
_NONDET = re.compile(
    r"(^|\.)time\.(time|time_ns|monotonic)$|"
    r"(^|\.)datetime\.(now|utcnow|today)$|"
    r"(^|\.)uuid\.uuid[14]$|"
    r"^random\.|"
    r"^(np|numpy)\.random\.(?!default_rng|Generator|SeedSequence)")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name for Name/Attribute chains ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif isinstance(node, ast.Call):
        return _dotted(node.func)
    else:
        return ""
    return ".".join(reversed(parts))


def _tail(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _is_mutable_default(node: ast.AST) -> Optional[str]:
    """Why a default expression is a shared-mutable hazard, or None."""
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return f"{type(node).__name__.lower()} display"
    if isinstance(node, ast.Call):
        fn = _tail(_dotted(node.func))
        if fn in ("dict", "list", "set", "bytearray", "defaultdict",
                  "deque", "Counter", "OrderedDict"):
            return f"{fn}() call"
        # CamelCase constructor: one instance shared by every call /
        # every dataclass instance (the PR 1 ReftConfig() bug class)
        if fn[:1].isupper() and not fn.isupper():
            return f"shared {fn}() instance"
    return None


def _is_default_factory_field(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _tail(_dotted(node.func)) == "field"
            and any(kw.arg == "default_factory" for kw in node.keywords))


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._locks: List[str] = []        # names of with-held locks
        self._whiles = 0
        self._finally_unlink = 0           # try/finally-with-unlink depth
        self._funcs: List[str] = []
        self._dataclass = 0

    def _add(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(
            Finding(rule, self.path, getattr(node, "lineno", 0), msg))

    # ------------------------------------------------------------ defaults
    def _check_arg_defaults(self, node) -> None:
        a = node.args
        for d in list(a.defaults) + list(a.kw_defaults):
            if d is None:
                continue
            why = _is_mutable_default(d)
            if why:
                self._add("ANZ001", d,
                          f"mutable default in {node.name}(): {why}")

    def visit_FunctionDef(self, node):
        self._check_arg_defaults(node)
        self._funcs.append(node.name)
        self.generic_visit(node)
        self._funcs.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        # lambda defaults share the same hazard
        for d in list(node.args.defaults) + list(node.args.kw_defaults):
            if d is not None and _is_mutable_default(d):
                self._add("ANZ001", d, "mutable default in lambda")
        self.generic_visit(node)

    def visit_ClassDef(self, node):
        is_dc = any(
            _tail(_dotted(dec)) == "dataclass" for dec in node.decorator_list)
        if is_dc:
            for stmt in node.body:
                val = None
                if isinstance(stmt, ast.AnnAssign):
                    val = stmt.value
                elif isinstance(stmt, ast.Assign):
                    val = stmt.value
                if val is None or _is_default_factory_field(val):
                    continue
                why = _is_mutable_default(val)
                if why:
                    self._add(
                        "ANZ001", val,
                        f"mutable dataclass field default in {node.name}: "
                        f"{why} — use field(default_factory=...)")
        self.generic_visit(node)

    # ---------------------------------------------------------- lock scope
    def visit_With(self, node):
        held = []
        for item in node.items:
            name = _tail(_dotted(item.context_expr))
            if name and _LOCKY.search(name):
                held.append(name)
        self._locks.extend(held)
        for stmt in node.body:
            self.visit(stmt)
        for item in node.items:        # with-expressions themselves
            self.visit(item.context_expr)
        if held:
            del self._locks[-len(held):]

    visit_AsyncWith = visit_With

    def visit_While(self, node):
        self._whiles += 1
        self.generic_visit(node)
        self._whiles -= 1

    def visit_Try(self, node):
        for h in node.handlers:
            if h.type is None:
                self._add("ANZ005", h, "bare except")
        has_unlink = any(
            _tail(_dotted(c.func)) in ("unlink", "remove", "_cleanup_tmp")
            for stmt in node.finalbody
            for c in ast.walk(stmt) if isinstance(c, ast.Call))
        if has_unlink:
            self._finally_unlink += 1
            self.generic_visit(node)
            self._finally_unlink -= 1
        else:
            self.generic_visit(node)

    # --------------------------------------------------------------- calls
    def visit_Call(self, node):
        name = _dotted(node.func)
        tailn = _tail(name)

        # ANZ002: blocking while a lock is held (lexically)
        if self._locks:
            blocking = None
            if name in ("time.sleep", "sleep"):
                blocking = "time.sleep"
            elif tailn == "recv":
                blocking = f"{name}()"
            elif tailn == "fsync":
                blocking = "fsync"
            elif name == "open":
                blocking = "open()"
            elif tailn == "join" and self._thread_join(node):
                blocking = f"{name}()"
            if blocking:
                self._add("ANZ002",
                          node, f"{blocking} while holding "
                          f"{'/'.join(self._locks)}")

        # ANZ003: pipe send must sit under a tx lock
        if (tailn == "send" and isinstance(node.func, ast.Attribute)
                and _PIPEY.search(_tail(_dotted(node.func.value)) or "")
                and not self._locks):
            self._add("ANZ003", node,
                      f"{name}() outside any lock — concurrent senders "
                      f"interleave pickled frames")

        # ANZ004: tmp-file write without finally-unlink protection
        if name == "open" and node.args and not self._finally_unlink:
            mode = ""
            if len(node.args) > 1 and isinstance(node.args[1], ast.Constant):
                mode = str(node.args[1].value)
            target = node.args[0]
            tname = (_dotted(target) or
                     (target.value if isinstance(target, ast.Constant)
                      and isinstance(target.value, str) else ""))
            if _TMPY.search(str(tname)) and ("w" in mode or "x" in mode
                                             or not mode):
                self._add("ANZ004", node,
                          f"write to tmp path {tname!r} without a "
                          f"finally-unlink")

        # ANZ006: nondeterminism inside plan_* (seeded planners)
        if any(f.startswith("plan_") for f in self._funcs):
            if name and _NONDET.search(name):
                self._add("ANZ006", node,
                          f"{name}() in seeded planner "
                          f"{[f for f in self._funcs if f.startswith('plan_')][-1]}()")

        # ANZ007: sleep inside a while loop = polling
        if self._whiles and name in ("time.sleep", "sleep"):
            self._add("ANZ007", node,
                      "time.sleep in a while loop (polling) — prefer an "
                      "Event/Condition wait")

        self.generic_visit(node)

    @staticmethod
    def _thread_join(node: ast.Call) -> bool:
        """Discriminate thread/process .join() from str.join(iterable):
        str.join always takes exactly one non-numeric positional arg."""
        if node.keywords:
            return any(kw.arg == "timeout" for kw in node.keywords)
        if not node.args:
            return True
        return (len(node.args) == 1
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, (int, float)))


def _pragmas(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _PRAGMA.search(line)
        if m:
            out[i] = {t.strip() for t in m.group(1).replace(",", " ").split()
                      if t.strip()}
    return out


def lint_source(source: str, path: str = "<string>",
                suppressed_out: Optional[list] = None) -> List[Finding]:
    """Lint one module's source; pragma-suppressed findings are dropped
    (and appended to `suppressed_out` when given, for reporting)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("ANZ000", path, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    v = _Visitor(path)
    v.visit(tree)
    pragmas = _pragmas(source)
    kept: List[Finding] = []
    for f in sorted(v.findings, key=lambda f: (f.line, f.rule)):
        ok = pragmas.get(f.line, set()) | pragmas.get(f.line - 1, set())
        if f.rule in ok or "*" in ok:
            if suppressed_out is not None:
                suppressed_out.append(f)
            continue
        kept.append(f)
    return kept


def lint_file(path: Path, suppressed_out: Optional[list] = None
              ) -> List[Finding]:
    return lint_source(path.read_text(encoding="utf-8"), str(path),
                       suppressed_out)


def iter_py(root: Path) -> Iterable[Path]:
    if root.is_file():
        yield root
        return
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


def lint_paths(paths: Iterable[Path],
               suppressed_out: Optional[list] = None) -> List[Finding]:
    out: List[Finding] = []
    for root in paths:
        for p in iter_py(Path(root)):
            out.extend(lint_file(p, suppressed_out))
    return out
