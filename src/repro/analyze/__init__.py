"""repro.analyze — concurrency & protocol analysis suite.

Three parts (see docs/API.md "Analysis & invariants"):
  * `repro.analyze.lint`      — repo-specific AST lint rules (ANZ0xx)
  * `repro.analyze.lockgraph` — runtime lock-order / deadlock checker
  * `repro.analyze.protocol`  — SMP protocol model checker + validator

Kept import-light on purpose: `core.*` modules import
`repro.analyze.lockgraph` (stdlib-only) at module load, so nothing here
may pull in numpy or the rest of the repro package.
"""
from repro.analyze.lockgraph import (  # noqa: F401
    named_lock, named_rlock, named_condition)
