"""SMP protocol model checker + runtime trace validator (part 3).

The trainer <-> SMP wire protocol (`core/smp.py`) is the reliability
core of the reproduction: a demultiplexed, seq-tagged pipe carrying
``ready -> begin -> bucket* -> end`` snapshot flights interleaved with
async ``persist``/``persisted`` exchanges, refcounted buffer pins and
stale-seq discard.  PR 5's desync and PR 8's close-during-flight race
both lived here.  This module encodes that FSM once, as data, and uses
it twice:

  * `TraceValidator` — a cheap runtime monitor `SMPHandle` feeds every
    sent/received message (behind ``ReftConfig.trace_protocol``), plus a
    `ServerValidator` for the SMP-side pin/selection invariants.  Any
    deviation raises `ProtocolViolation` loudly instead of wedging.
  * `model_check` — an explicit-state bounded model checker that
    exhaustively enumerates interleavings of snapshots, in-flight
    persists, persist timeouts, a stop and an SMP death against the SAME
    flight table, proving no reachable wedge / double-unpin / torn
    persist / desync within the bound.

Reading a counterexample: each violation carries ``trace`` — the exact
action sequence (``t:begin#1``, ``s:persist#2``, ``w:done#2`` ...) from
the initial state to the bad transition; ``t:`` = trainer, ``s:`` = SMP
message loop, ``w:`` = SMP persist worker.  Replay it mentally against
`core/smp.py` — every label maps 1:1 to a code path.
"""
from __future__ import annotations

import threading
from collections import deque, namedtuple
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "ProtocolViolation", "FLIGHT_FSM", "TraceValidator", "ServerValidator",
    "CheckConfig", "CheckResult", "model_check",
]


class ProtocolViolation(RuntimeError):
    """A message that the SMP protocol FSM does not allow."""


# --------------------------------------------------------------- the table
# Snapshot-flight phase machine, keyed (phase, op) -> next phase.  This is
# the single source of truth: TraceValidator gates trainer->SMP sends with
# it and the model checker gates the abstract trainer's actions with it.
# `persist`/`ping` are phase-preserving (they interleave with flights);
# `stop` is legal from idle (clean close) AND mid-flight (kill/teardown
# paths abandon the open flight by design).
FLIGHT_FSM: Dict[Tuple[str, str], str] = {
    ("start", "ready"): "idle",      # SMP hello, consumed once at come-up
    ("idle", "begin"): "open",
    ("open", "bucket"): "open",
    ("open", "end"): "idle",
    ("idle", "persist"): "idle",
    ("open", "persist"): "open",
    ("idle", "ping"): "idle",
    ("open", "ping"): "open",
    ("idle", "stop"): "stopped",
    ("open", "stop"): "stopped",
}


# ---------------------------------------------------------------- runtime
class TraceValidator:
    """Trainer-side runtime monitor for one `SMPHandle`'s pipe traffic.

    Thread-safe; every check is O(1) dict/deque work so it can stay on in
    CI (the micro benchmark gates its saving-path overhead at < 5%).
    Post-stop persist replies are tolerated (close-during-persist drains
    late ``persisted`` messages); everything else off-table raises.
    """

    def __init__(self, name: str = "smp", fsm: Optional[dict] = None,
                 strict: bool = True):
        self.name = name
        self.fsm = FLIGHT_FSM if fsm is None else fsm
        self.strict = strict
        self._mu = threading.Lock()
        self.phase = "start"
        self._open_step: Optional[int] = None
        self._expect_clean: deque = deque()
        self._expect_base: deque = deque()
        self._pings = 0
        self._outstanding: set = set()
        self._stale: set = set()
        self.events = 0
        self.violations: List[str] = []

    def _bad(self, why: str) -> None:
        msg = f"[{self.name}] protocol violation: {why}"
        self.violations.append(msg)
        if self.strict:
            raise ProtocolViolation(msg)

    # -- trainer -> SMP ---------------------------------------------------
    def tx(self, msg: tuple) -> None:
        op = msg[0]
        with self._mu:
            self.events += 1
            if op in ("begin", "bucket", "end", "stop", "ping", "persist"):
                nxt = self.fsm.get((self.phase, op))
                if nxt is None:
                    self._bad(f"tx {op!r} illegal in phase {self.phase!r}")
                    return
                self.phase = nxt
            if op == "begin":
                self._open_step = msg[1]
                if len(msg) > 2 and msg[2] is not None:
                    self._expect_base.append(msg[1])  # delta flight: ack due
            elif op == "end":
                if msg[1] != self._open_step:
                    self._bad(f"end step {msg[1]} != open step "
                              f"{self._open_step}")
                    return
                self._expect_clean.append(msg[1])
                self._open_step = None
            elif op == "persist":
                seq = msg[1]
                if seq in self._outstanding or seq in self._stale:
                    self._bad(f"persist seq {seq} reused")
                    return
                self._outstanding.add(seq)
            elif op == "ping":
                self._pings += 1

    # -- SMP -> trainer ---------------------------------------------------
    def rx(self, msg: tuple) -> None:
        tag = msg[0]
        with self._mu:
            self.events += 1
            if tag == "ready":
                nxt = self.fsm.get((self.phase, "ready"))
                if nxt is None:
                    self._bad(f"duplicate ready in phase {self.phase!r}")
                    return
                self.phase = nxt
            elif tag == "clean":
                if not self._expect_clean:
                    self._bad(f"clean({msg[1]}) with no flight ended")
                elif self._expect_clean[0] != msg[1]:
                    self._bad(f"clean({msg[1]}) but oldest ended flight is "
                              f"{self._expect_clean[0]} (desync)")
                else:
                    self._expect_clean.popleft()
            elif tag == "base":
                if not self._expect_base or self._expect_base[0] != msg[1]:
                    self._bad(f"base ack for step {msg[1]} never requested")
                else:
                    self._expect_base.popleft()
            elif tag == "pong":
                if self._pings <= 0:
                    self._bad("pong with no ping outstanding")
                else:
                    self._pings -= 1
            elif tag in ("persisted", "persist-error"):
                seq = msg[1]
                if seq in self._outstanding:
                    self._outstanding.discard(seq)
                elif seq in self._stale:
                    self._stale.discard(seq)   # tolerated late reply
                else:
                    self._bad(f"{tag} for unknown seq {seq} (desync)")
            elif tag == "protocol-error":
                self._bad(f"SMP-side: {msg[1]}")

    def mark_stale(self, seq: int) -> None:
        """persist_result timed out on `seq`: its late reply is legal."""
        with self._mu:
            self._outstanding.discard(seq)
            self._stale.add(seq)

    def snapshot(self) -> dict:
        with self._mu:
            return {"phase": self.phase, "events": self.events,
                    "outstanding": sorted(self._outstanding),
                    "stale": sorted(self._stale),
                    "violations": list(self.violations)}


class ServerValidator:
    """SMP-side invariants, checked in `_smp_main` when tracing is on.
    Methods return a violation string (the loop ships it back as a
    ``("protocol-error", text)`` message) or None."""

    @staticmethod
    def on_begin_select(selected: int, latest: int, pinned) -> Optional[str]:
        if selected == latest:
            return (f"begin selected buffer {selected} which is the "
                    f"published latest (would tear the clean snapshot)")
        if selected in pinned:
            return (f"begin selected pinned buffer {selected} "
                    f"(persist in flight would read torn bytes)")
        return None

    @staticmethod
    def on_unpin(idx: int, count_before: int) -> Optional[str]:
        if count_before <= 0:
            return f"double-unpin of buffer {idx} (refcount {count_before})"
        return None

    @staticmethod
    def on_persist_done(idx: int, job_step: int, buf_step: int,
                        buf_state_clean: bool) -> Optional[str]:
        if not buf_state_clean or buf_step != job_step:
            return (f"torn persist: buffer {idx} mutated under pin "
                    f"(job step {job_step}, buffer now step {buf_step}, "
                    f"clean={buf_state_clean})")
        return None


# ----------------------------------------------------------- model checker
# Abstract state.  Everything hashable/frozen so BFS can dedup.
#   tphase       trainer flight phase ("idle"/"open"/"stopped")
#   tstep        step of the current/next flight (1-based)
#   eclean       FIFO of steps whose `clean` ack is due
#   outst        frozenset of seqs awaiting persist replies
#   stale        frozenset of timed-out seqs (late replies legal)
#   fired        persists fired so far
#   q_ts / q_st  message queues trainer->SMP / SMP->trainer
#   dirty        SMP's open dirty buffer (-1 = none)
#   latest       published clean buffer (-1 = none)
#   bufs         3 x (step, state) with state in {"inv","dirty","clean"}
#   pins         3 x refcount
#   wq / wbusy   persist worker queue / running job (seq, idx, step)
#   alive        SMP process alive
#   sstop        SMP message loop saw `stop`
_S = namedtuple("_S", "tphase tstep eclean outst stale fired q_ts q_st "
                      "dirty latest bufs pins wq wbusy alive sstop")


@dataclass
class CheckConfig:
    max_snapshots: int = 2
    max_persists: int = 2
    allow_timeout: bool = True
    allow_death: bool = True
    fsm: Dict[Tuple[str, str], str] = field(
        default_factory=lambda: dict(FLIGHT_FSM))
    # fault-injection variants for self-tests of the checker itself:
    #   "unpin-before-pin"   persist skips the select-time pin (worker's
    #                        unpin then drives the refcount negative)
    #   "begin-picks-latest" begin may select the published buffer
    variant: Optional[str] = None
    max_states: int = 2_000_000


@dataclass
class CheckResult:
    states: int = 0
    transitions: int = 0
    violations: List[dict] = field(default_factory=list)
    wedges: List[dict] = field(default_factory=list)
    complete: bool = True     # False if max_states cut exploration short

    @property
    def ok(self) -> bool:
        return self.complete and not self.violations and not self.wedges


def _initial() -> _S:
    return _S("idle", 1, (), frozenset(), frozenset(), 0, (), (),
              -1, -1, ((0, "inv"),) * 3, (0, 0, 0), (), None, True, False)


def _succ(s: _S, cfg: CheckConfig):
    """Yield (label, next_state_or_None, violation_or_None)."""
    out = []

    def emit(label, **repl):
        out.append((label, s._replace(**repl), None))

    def bad(label, why):
        out.append((label, None, why))

    # ---- trainer actions (pipe usable only while the SMP lives) ----
    if s.alive:
        if (s.tstep <= cfg.max_snapshots
                and cfg.fsm.get((s.tphase, "begin"))):
            emit(f"t:begin#{s.tstep}",
                 tphase=cfg.fsm[(s.tphase, "begin")],
                 q_ts=s.q_ts + (("begin", s.tstep),))
        if s.tphase == "open" and cfg.fsm.get((s.tphase, "end")):
            emit(f"t:end#{s.tstep}",
                 tphase=cfg.fsm[(s.tphase, "end")],
                 tstep=s.tstep + 1,
                 eclean=s.eclean + (s.tstep,),
                 q_ts=s.q_ts + (("end", s.tstep),))
        if (s.fired < cfg.max_persists
                and cfg.fsm.get((s.tphase, "persist"))):
            seq = s.fired + 1
            emit(f"t:persist#{seq}",
                 fired=seq,
                 outst=s.outst | {seq},
                 q_ts=s.q_ts + (("persist", seq),))
        if cfg.fsm.get((s.tphase, "stop")):
            emit("t:stop",
                 tphase=cfg.fsm[(s.tphase, "stop")],
                 q_ts=s.q_ts + (("stop",),))
        if cfg.allow_timeout:
            for seq in sorted(s.outst):
                emit(f"t:timeout#{seq}",
                     outst=s.outst - {seq}, stale=s.stale | {seq})
        if s.q_st:                                   # trainer recv + demux
            msg, rest = s.q_st[0], s.q_st[1:]
            tag = msg[0]
            lbl = f"t:recv-{tag}" + (f"#{msg[1]}" if len(msg) > 1 else "")
            if tag == "clean":
                if not s.eclean or s.eclean[0] != msg[1]:
                    bad(lbl, f"desync: clean({msg[1]}) but expected "
                             f"{s.eclean[:1] or None}")
                else:
                    emit(lbl, eclean=s.eclean[1:], q_st=rest)
            elif tag in ("persisted", "persist-error"):
                seq = msg[1]
                if seq in s.outst:
                    emit(lbl, outst=s.outst - {seq}, q_st=rest)
                elif seq in s.stale:
                    emit(lbl, stale=s.stale - {seq}, q_st=rest)
                else:
                    bad(lbl, f"desync: {tag} for unknown seq {seq}")
            else:
                emit(lbl, q_st=rest)

    # ---- SMP message loop ----
    if s.alive and not s.sstop and s.q_ts:
        msg, rest = s.q_ts[0], s.q_ts[1:]
        op = msg[0]
        if op == "begin":
            step = msg[1]
            pinned = {i for i in range(3) if s.pins[i] > 0}
            cands = [i for i in range(3)
                     if i != s.latest and i not in pinned]
            if (cfg.variant == "begin-picks-latest" and s.latest >= 0
                    and s.latest not in pinned):
                cands = [s.latest]    # buggy selection: reuse the published
            if cands:          # else: pin_cond.wait — message stays queued
                sel = min(cands, key=lambda i: (s.bufs[i][0], i))
                why = ServerValidator.on_begin_select(sel, s.latest, pinned)
                if why:
                    bad(f"s:begin#{step}", why)
                else:
                    bufs = list(s.bufs)
                    bufs[sel] = (step, "dirty")
                    emit(f"s:begin#{step}", dirty=sel,
                         bufs=tuple(bufs), q_ts=rest)
        elif op == "end":
            step = msg[1]
            bufs = list(s.bufs)
            bufs[s.dirty] = (step, "clean")
            emit(f"s:end#{step}", latest=s.dirty, dirty=-1,
                 bufs=tuple(bufs), q_ts=rest,
                 q_st=s.q_st + (("clean", step),))
        elif op == "persist":
            seq = msg[1]
            if s.latest < 0:
                emit(f"s:persist#{seq}-nosnap", q_ts=rest,
                     q_st=s.q_st + (("persist-error", seq),))
            else:
                idx = s.latest
                pins = list(s.pins)
                if cfg.variant != "unpin-before-pin":
                    pins[idx] += 1
                emit(f"s:persist#{seq}", pins=tuple(pins), q_ts=rest,
                     wq=s.wq + ((seq, idx, s.bufs[idx][0]),))
        elif op == "stop":
            emit("s:stop", sstop=True, q_ts=rest)

    # ---- SMP persist worker (keeps draining after stop) ----
    if s.alive:
        if s.wbusy is None and s.wq:
            emit("w:take", wbusy=s.wq[0], wq=s.wq[1:])
        elif s.wbusy is not None:
            seq, idx, step = s.wbusy
            bstep, bstate = s.bufs[idx]
            lbl = f"w:done#{seq}"
            why = ServerValidator.on_persist_done(
                idx, step, bstep, bstate == "clean")
            if why is None:
                why = ServerValidator.on_unpin(idx, s.pins[idx])
            if why:
                bad(lbl, why)
            else:
                pins = list(s.pins)
                pins[idx] -= 1
                emit(lbl, pins=tuple(pins), wbusy=None,
                     q_st=s.q_st + (("persisted", seq, step),))

    # ---- SMP death (at most once; alive=False is absorbing) ----
    if cfg.allow_death and s.alive:
        emit("x:death", alive=False)

    return out


def _trace(parents: dict, state: _S, last_label: str) -> List[str]:
    labels = [last_label]
    while state in parents:
        state, lbl = parents[state]
        labels.append(lbl)
    return list(reversed(labels[:-1]))    # drop the root's None marker


def model_check(cfg: Optional[CheckConfig] = None) -> CheckResult:
    """BFS the bounded protocol state space; every reachable transition is
    taken, every invariant checked on the way."""
    cfg = cfg or CheckConfig()
    res = CheckResult()
    root = _initial()
    seen = {root}
    parents: Dict[_S, tuple] = {root: (None, None)}
    frontier = deque([root])
    while frontier:
        s = frontier.popleft()
        res.states += 1
        if res.states > cfg.max_states:
            res.complete = False
            break
        succ = _succ(s, cfg)
        if not succ:
            # terminal: fine unless the system still owes progress while
            # everything is healthy — that is a wedge (deadlock)
            owes = (s.tphase == "open" or s.eclean or s.outst
                    or s.q_ts or s.q_st or s.wq or s.wbusy is not None)
            if s.alive and owes:
                res.wedges.append(
                    {"state": s._asdict(),
                     "trace": _trace(parents, s, "<no enabled action>")})
            continue
        for label, nxt, why in succ:
            res.transitions += 1
            if why is not None:
                res.violations.append(
                    {"kind": why, "action": label,
                     "trace": _trace(parents, s, label)})
                continue
            if nxt not in seen:
                seen.add(nxt)
                parents[nxt] = (s, label)
                frontier.append(nxt)
    return res
