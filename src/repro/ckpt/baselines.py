"""Asynchronous checkpointing baselines (paper §6.1).

* CheckFreq-style  — fully asynchronous checkpointing: overlapped d2h copy +
  serialization + storage I/O of the FULL state per node (no sharding).
* TorchSnapshot-style — sharded asynchronous checkpointing: state is sharded
  along DP paths; every rank serializes and persists only its 1/m byte
  range, with parallel I/O.

Both write the same on-disk format, loadable by `load_checkpoint`.  The
benchmark harness times the phases separately (snapshot/d2h, serialize,
persist) to reproduce Figure 9's decomposition.
"""
from __future__ import annotations

import os
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.snapshot import _LeafReader
from repro.core.treebytes import (
    FlatSpec, buffer_to_tree, leaf_arrays, make_flat_spec,
)


@dataclass
class PhaseTimes:
    d2h: float = 0.0
    serialize: float = 0.0
    persist: float = 0.0
    total: float = 0.0


class AsyncCheckpointer:
    """Common machinery; `shard=False` -> CheckFreq, True -> TorchSnapshot."""

    name = "async-ckpt"

    def __init__(self, out_dir: str, state_template: Any, *,
                 n_ranks: int = 1, shard: bool = False,
                 bucket_bytes: int = 16 << 20, fsync: bool = False):
        self.dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.spec = make_flat_spec(state_template)
        self.n_ranks = n_ranks
        self.shard = shard
        self.bucket_bytes = bucket_bytes
        self.fsync = fsync
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self.last_times = PhaseTimes()
        self.last_step = -1

    # ------------------------------------------------------------ ranges
    def _rank_range(self, rank: int):
        total = self.spec.total_bytes
        if not self.shard:
            return 0, total
        per = -(-total // self.n_ranks)
        return min(rank * per, total), min((rank + 1) * per, total)

    # -------------------------------------------------------------- save
    def save_async(self, state: Any, step: int) -> bool:
        if self._thread is not None and self._thread.is_alive():
            return False                      # previous ckpt still in flight
        self._raise_pending()
        leaves = leaf_arrays(state)
        self._thread = threading.Thread(target=self._run,
                                        args=(leaves, int(step)), daemon=True)
        self._thread.start()
        return True

    def save_sync(self, state: Any, step: int) -> PhaseTimes:
        assert self.save_async(state, step)
        self.wait()
        return self.last_times

    def wait(self, timeout: float = 600.0):
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _run(self, leaves, step):
        try:
            t_all = time.time()
            times = PhaseTimes()
            # phase 1: d2h ("snapshotting") of every rank's range
            t0 = time.time()
            reader = _LeafReader(self.spec, leaves)
            bufs: Dict[int, np.ndarray] = {}
            for r in range(self.n_ranks):
                lo, hi = self._rank_range(r)
                buf = np.empty(hi - lo, np.uint8)
                reader.read(lo, hi, buf)
                bufs[r] = buf
                if not self.shard:
                    break                      # every rank copies the same
            times.d2h = time.time() - t0

            # phase 2: serialization (byte-stream framing, paper step 2)
            t0 = time.time()
            blobs: Dict[int, bytes] = {}
            for r, buf in bufs.items():
                lo, hi = self._rank_range(r)
                head = {"step": step, "rank": r, "lo": lo, "hi": hi,
                        "n_ranks": self.n_ranks if self.shard else 1,
                        "spec": self.spec.to_json()}
                blobs[r] = pickle.dumps(head) + buf.tobytes()
            times.serialize = time.time() - t0

            # phase 3: persist (parallel I/O for the sharded variant)
            t0 = time.time()
            threads = []
            for r, blob in blobs.items():
                th = threading.Thread(target=self._write, args=(step, r, blob))
                th.start()
                threads.append(th)
            for th in threads:
                th.join()
            times.persist = time.time() - t0
            times.total = time.time() - t_all
            self.last_times = times
            self.last_step = step
        except BaseException as e:
            self._err = e

    def _write(self, step, rank, blob):
        path = os.path.join(self.dir, f"ckpt-{step}-r{rank}.bin")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)


class CheckFreqCheckpointer(AsyncCheckpointer):
    """Fully asynchronous, unsharded (CheckFreq [15])."""
    name = "checkfreq"

    def __init__(self, out_dir, state_template, **kw):
        kw.pop("shard", None)
        super().__init__(out_dir, state_template, shard=False, **kw)


class TorchSnapshotCheckpointer(AsyncCheckpointer):
    """Sharded along DP paths with parallel I/O (TorchSnapshot [16])."""
    name = "torchsnapshot"

    def __init__(self, out_dir, state_template, *, n_ranks, **kw):
        kw.pop("shard", None)
        super().__init__(out_dir, state_template, n_ranks=n_ranks,
                         shard=True, **kw)


def load_checkpoint(out_dir: str, step: int, template: Any) -> Any:
    """Reassemble a checkpoint written by either baseline."""
    files = sorted(f for f in os.listdir(out_dir)
                   if f.startswith(f"ckpt-{step}-r"))
    if not files:
        raise FileNotFoundError(f"no checkpoint for step {step} in {out_dir}")
    buf = None
    spec = None
    for fn in files:
        with open(os.path.join(out_dir, fn), "rb") as f:
            head = pickle.load(f)
            payload = np.frombuffer(f.read(), np.uint8)
        spec = FlatSpec.from_json(head["spec"])
        if buf is None:
            buf = np.zeros(spec.total_bytes, np.uint8)
        buf[head["lo"]:head["hi"]] = payload[:head["hi"] - head["lo"]]
        if head["n_ranks"] == 1:
            break
    return buffer_to_tree(template, spec, buf)
