"""Disk checkpointing: retention manager + legacy baseline names.

The ad-hoc baseline drivers that used to live in `repro.ckpt.baselines`
were absorbed into the unified facade (`repro.api.disk`); the historical
class names remain importable here for existing tests and scripts.
"""
from repro.api.disk import (
    DiskWriter, PhaseTimes, latest_complete_step, load_checkpoint,
)
from repro.ckpt.manager import CheckpointManager, scan_shards

# legacy aliases (paper §6.1 naming)
AsyncCheckpointer = DiskWriter


class CheckFreqCheckpointer(DiskWriter):
    """Fully asynchronous, unsharded (CheckFreq [15])."""
    name = "checkfreq"

    def __init__(self, out_dir, state_template, **kw):
        kw.pop("shard", None)
        super().__init__(out_dir, state_template, shard=False, **kw)


class TorchSnapshotCheckpointer(DiskWriter):
    """Sharded along DP paths with parallel I/O (TorchSnapshot [16])."""
    name = "torchsnapshot"

    def __init__(self, out_dir, state_template, *, n_ranks, **kw):
        kw.pop("shard", None)
        super().__init__(out_dir, state_template, n_ranks=n_ranks,
                         shard=True, **kw)


__all__ = ["AsyncCheckpointer", "CheckFreqCheckpointer", "CheckpointManager",
           "DiskWriter", "PhaseTimes", "TorchSnapshotCheckpointer",
           "latest_complete_step", "load_checkpoint", "scan_shards"]
