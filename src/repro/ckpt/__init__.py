from repro.ckpt.baselines import (
    AsyncCheckpointer, CheckFreqCheckpointer, TorchSnapshotCheckpointer,
    load_checkpoint,
)

__all__ = ["AsyncCheckpointer", "CheckFreqCheckpointer",
           "TorchSnapshotCheckpointer", "load_checkpoint"]
