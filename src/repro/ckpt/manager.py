"""Checkpoint retention manager for the REFT-Ckpt tier.

Production hygiene around the rare persisted checkpoints: an atomic
manifest of complete checkpoints (a step counts only when every SG
member's shard landed), keep-latest-k garbage collection, and discovery
for recovery.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

MANIFEST = "MANIFEST.json"


def scan_shards(ckpt_dir: str) -> Dict[int, List[int]]:
    """{step: [nodes present]} from the files on disk.  Delegates to the
    single anchored-regex parser (`recovery.checkpoint_families`) so GC
    and restore can never disagree on family membership."""
    from repro.core.recovery import checkpoint_families
    return {s: sorted(ns)
            for s, ns in checkpoint_families(ckpt_dir).items()}


def plan_gc(families: Dict[int, list], complete: set, keep_steps: set,
            spare_newest_torn: bool = False,
            inflight=()) -> List[int]:
    """Steps to delete under keep-k-complete retention.

    One retention policy for every checkpoint layout (REFT shard families
    and disk ckpt families): complete families survive iff in
    `keep_steps`; torn families are garbage, except — when
    `spare_newest_torn` — the single newest torn family above the newest
    kept step, which may be a persist currently in flight.  `inflight`
    explicitly names steps with REGISTERED in-flight persists (the async
    REFT-Ckpt path): their still-growing families are never GC fodder, no
    matter how many of them are in the air or where they sit relative to
    the kept steps."""
    spare = {int(s) for s in inflight}
    if spare_newest_torn:
        newest_kept = max(keep_steps) if keep_steps else -1
        newest_torn = max((s for s in families
                           if s not in complete and s > newest_kept),
                          default=None)
        if newest_torn is not None:
            spare.add(newest_torn)
    return [s for s in families
            if s not in spare and not (s in complete and s in keep_steps)]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, n_members: int, *, keep: int = 3,
                 store=None, remote_prefix: str = "families"):
        self.dir = ckpt_dir
        self.n = n_members
        self.keep = keep
        self.store = store               # tier-4 ObjectStore (optional):
        self.remote_prefix = remote_prefix   # remote families join
        self._inflight: set = set()      # latest()/GC on equal footing
        os.makedirs(ckpt_dir, exist_ok=True)   # inflight steps: GC-exempt

    # --------------------------------------------------- in-flight gate
    def register_inflight(self, step: int) -> None:
        """Declare an async persist for `step` in flight: its (growing,
        currently torn) family is exempt from GC until resolved, so a
        commit racing the background write can never tear it."""
        self._inflight.add(int(step))

    def resolve_inflight(self, step: int) -> None:
        self._inflight.discard(int(step))

    def inflight_steps(self) -> List[int]:
        return sorted(self._inflight)

    # ------------------------------------------------------------ state
    def complete_steps(self) -> List[int]:
        """Steps for which every member's shard is on disk."""
        return sorted(s for s, nodes in scan_shards(self.dir).items()
                      if nodes == list(range(self.n)))

    def remote_complete_steps(self) -> List[int]:
        """Steps with a COMPLETE remote family (manifest present — the
        marker is written only after every shard object composed).
        Empty without a store or when the store is unreachable."""
        if self.store is None:
            return []
        from repro.store.base import StoreError
        from repro.store.manifest import object_families
        try:
            return sorted(object_families(self.store, self.remote_prefix))
        except StoreError:
            return []

    def latest(self) -> Optional[int]:
        """Newest COMPLETE, fully-landed step — local `.reft` families
        and manifest-complete remote families on equal footing; a family
        whose async persist is still in flight is never reported (its
        shards may all exist while a final fsync or manifest write is
        pending)."""
        steps = [s for s in set(self.complete_steps())
                 | set(self.remote_complete_steps())
                 if s not in self._inflight]
        return max(steps) if steps else None

    # --------------------------------------------------------- manifest
    def commit(self) -> dict:
        """Atomically publish the manifest and GC beyond keep-latest-k."""
        steps = self.complete_steps()
        kept = steps[-self.keep:] if self.keep else steps
        manifest = {"n_members": self.n, "complete_steps": kept}
        if self.store is not None:
            manifest["remote_steps"] = self.remote_complete_steps()
        tmp = os.path.join(self.dir, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.dir, MANIFEST))
        self._gc(set(kept))
        self._gc_remote()
        return manifest

    def read_manifest(self) -> Optional[dict]:
        try:
            with open(os.path.join(self.dir, MANIFEST)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def _gc(self, keep_steps: set) -> int:
        """Drop superseded complete steps AND torn (incomplete) families.

        Torn families used to survive whenever their step was >= the newest
        kept step, so every crashed partial checkpoint leaked forever; see
        `plan_gc` for the policy (a possibly in-flight newest torn family
        is spared)."""
        removed = 0
        shards = scan_shards(self.dir)
        complete = {s for s, nodes in shards.items()
                    if nodes == list(range(self.n))}
        for s in plan_gc(shards, complete, set(keep_steps),
                         spare_newest_torn=True, inflight=self._inflight):
            for node in shards[s]:
                try:
                    os.remove(os.path.join(
                        self.dir, f"step-{s}-node-{node}.reft"))
                    removed += 1
                except FileNotFoundError:
                    pass
        return removed

    def _gc_remote(self) -> int:
        """Same keep-k policy over remote families: complete = manifest
        present; torn = shard/part objects with no manifest (a crashed
        upload's orphans).  Store errors skip the sweep — retention is
        best-effort, never a persist-path failure."""
        if self.store is None:
            return 0
        from repro.store.base import StoreError
        from repro.store.manifest import delete_family, list_step_prefixes
        try:
            complete = set(self.remote_complete_steps())
            families = {s: None
                        for s in list_step_prefixes(self.store,
                                                    self.remote_prefix)}
            kept = sorted(complete)[-self.keep:] if self.keep \
                else sorted(complete)
            removed = 0
            for s in plan_gc(families, complete, set(kept),
                             spare_newest_torn=True,
                             inflight=self._inflight):
                removed += delete_family(self.store, self.remote_prefix, s)
            return removed
        except StoreError:
            return 0
