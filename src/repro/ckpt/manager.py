"""Checkpoint retention manager for the REFT-Ckpt tier.

Production hygiene around the rare persisted checkpoints: an atomic
manifest of complete checkpoints (a step counts only when every SG
member's shard landed), keep-latest-k garbage collection, and discovery
for recovery.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

MANIFEST = "MANIFEST.json"


def scan_shards(ckpt_dir: str) -> Dict[int, List[int]]:
    """{step: [nodes present]} from the files on disk.  Delegates to the
    single anchored-regex parser (`recovery.checkpoint_families`) so GC
    and restore can never disagree on family membership."""
    from repro.core.recovery import checkpoint_families
    return {s: sorted(ns)
            for s, ns in checkpoint_families(ckpt_dir).items()}


def plan_gc(families: Dict[int, list], complete: set, keep_steps: set,
            spare_newest_torn: bool = False) -> List[int]:
    """Steps to delete under keep-k-complete retention.

    One retention policy for every checkpoint layout (REFT shard families
    and disk ckpt families): complete families survive iff in
    `keep_steps`; torn families are garbage, except — when
    `spare_newest_torn` — the single newest torn family above the newest
    kept step, which may be a persist currently in flight."""
    spare = None
    if spare_newest_torn:
        newest_kept = max(keep_steps) if keep_steps else -1
        spare = max((s for s in families
                     if s not in complete and s > newest_kept), default=None)
    return [s for s in families
            if s != spare and not (s in complete and s in keep_steps)]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, n_members: int, *, keep: int = 3):
        self.dir = ckpt_dir
        self.n = n_members
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)

    # ------------------------------------------------------------ state
    def complete_steps(self) -> List[int]:
        """Steps for which every member's shard is on disk."""
        return sorted(s for s, nodes in scan_shards(self.dir).items()
                      if nodes == list(range(self.n)))

    def latest(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    # --------------------------------------------------------- manifest
    def commit(self) -> dict:
        """Atomically publish the manifest and GC beyond keep-latest-k."""
        steps = self.complete_steps()
        kept = steps[-self.keep:] if self.keep else steps
        manifest = {"n_members": self.n, "complete_steps": kept}
        tmp = os.path.join(self.dir, MANIFEST + ".tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.dir, MANIFEST))
        self._gc(set(kept))
        return manifest

    def read_manifest(self) -> Optional[dict]:
        try:
            with open(os.path.join(self.dir, MANIFEST)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def _gc(self, keep_steps: set) -> int:
        """Drop superseded complete steps AND torn (incomplete) families.

        Torn families used to survive whenever their step was >= the newest
        kept step, so every crashed partial checkpoint leaked forever; see
        `plan_gc` for the policy (a possibly in-flight newest torn family
        is spared)."""
        removed = 0
        shards = scan_shards(self.dir)
        complete = {s for s, nodes in shards.items()
                    if nodes == list(range(self.n))}
        for s in plan_gc(shards, complete, set(keep_steps),
                         spare_newest_torn=True):
            for node in shards[s]:
                try:
                    os.remove(os.path.join(
                        self.dir, f"step-{s}-node-{node}.reft"))
                    removed += 1
                except FileNotFoundError:
                    pass
        return removed
