"""Checkpoint retention manager for the REFT-Ckpt tier.

Production hygiene around the rare persisted checkpoints: an atomic
manifest of complete checkpoints (a step counts only when every SG
member's shard landed), keep-latest-k garbage collection, and discovery
for recovery.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

MANIFEST = "MANIFEST.json"


def scan_shards(ckpt_dir: str) -> Dict[int, List[int]]:
    """{step: [nodes present]} from the files on disk.  Delegates to the
    single anchored-regex parser (`recovery.checkpoint_families`) so GC
    and restore can never disagree on family membership."""
    from repro.core.recovery import checkpoint_families
    return {s: sorted(ns)
            for s, ns in checkpoint_families(ckpt_dir).items()}


def _chain_closure(steps, deps: Dict[int, int]) -> set:
    """`steps` plus every chain ancestor reachable through `deps`
    (step -> base_step edges); cycle-safe."""
    out: set = set()
    for s in steps:
        cur = int(s)
        while cur not in out:
            out.add(cur)
            if cur not in deps:
                break
            cur = int(deps[cur])
    return out


def plan_gc(families: Dict[int, list], complete: set, keep_steps: set,
            spare_newest_torn: bool = False,
            inflight=(), deps: Optional[Dict[int, int]] = None) -> List[int]:
    """Steps to delete under keep-k-complete retention.

    One retention policy for every checkpoint layout (REFT shard families
    and disk ckpt families): complete families survive iff in
    `keep_steps`; torn families are garbage, except — when
    `spare_newest_torn` — the single newest torn family above the newest
    kept step, which may be a persist currently in flight.  `inflight`
    explicitly names steps with REGISTERED in-flight persists (the async
    REFT-Ckpt path): their still-growing families are never GC fodder, no
    matter how many of them are in the air or where they sit relative to
    the kept steps.

    `deps` (step -> base_step) carries the delta-chain edges: a keyframe
    or intermediate delta stays LIVE while any kept or spared step's
    chain passes through it (deleting it would orphan the dependents),
    and deletions CASCADE the other way — a step whose chain is torn
    anywhere below it is dead weight no matter how new it is."""
    deps = {int(k): int(v) for k, v in (deps or {}).items()}
    spare = {int(s) for s in inflight}
    if spare_newest_torn:
        newest_kept = max(keep_steps) if keep_steps else -1
        newest_torn = max((s for s in families
                           if s not in complete and s > newest_kept),
                          default=None)
        if newest_torn is not None:
            spare.add(newest_torn)
    # an in-flight or kept delta step needs its whole ancestry alive
    live = _chain_closure(set(keep_steps) | spare, deps)
    alive: Dict[int, bool] = {}

    def chain_ok(s: int) -> bool:
        if s in alive:
            return alive[s]
        alive[s] = False                         # cycle guard
        ok = s in complete and s in families
        if ok and s in deps:
            ok = chain_ok(deps[s])
        alive[s] = ok
        return ok

    return [s for s in families
            if s not in spare and not (s in live and chain_ok(s))]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, n_members: int, *, keep: int = 3,
                 store=None, remote_prefix: str = "families"):
        self.dir = ckpt_dir
        self.n = n_members
        self.keep = keep
        self.store = store               # tier-4 ObjectStore (optional):
        self.remote_prefix = remote_prefix   # remote families join
        self._inflight: set = set()      # latest()/GC on equal footing
        os.makedirs(ckpt_dir, exist_ok=True)   # inflight steps: GC-exempt

    # --------------------------------------------------- in-flight gate
    def register_inflight(self, step: int) -> None:
        """Declare an async persist for `step` in flight: its (growing,
        currently torn) family is exempt from GC until resolved, so a
        commit racing the background write can never tear it."""
        self._inflight.add(int(step))

    def resolve_inflight(self, step: int) -> None:
        self._inflight.discard(int(step))

    def inflight_steps(self) -> List[int]:
        return sorted(self._inflight)

    # ------------------------------------------------------------ state
    def complete_steps(self) -> List[int]:
        """Steps for which every member's shard is on disk — including
        delta steps whose whole `.reftd` chain down to a complete
        keyframe family is on disk (a torn link poisons dependents)."""
        from repro.core.recovery import restorable_steps
        return restorable_steps(self.dir, self.n)

    def _remote_manifests(self):
        """({step: manifest}, {step: base_step}) for every remote step
        whose manifest loads; deps only for delta manifests."""
        from repro.store.base import StoreError
        from repro.store.manifest import (
            load_manifest, manifest_base_step, object_families,
        )
        mans: Dict[int, dict] = {}
        for s in object_families(self.store, self.remote_prefix):
            try:
                mans[s] = load_manifest(self.store, self.remote_prefix, s)
            except StoreError:
                continue
        deps = {}
        for s, man in mans.items():
            base = manifest_base_step(man)
            if base is not None:
                deps[s] = base
        return mans, deps

    def remote_complete_steps(self) -> List[int]:
        """Steps with a COMPLETE remote family (manifest present — the
        marker is written only after every shard object composed); a
        delta family counts only when every manifest on its `base_step`
        chain exists down to a full one.  Empty without a store or when
        the store is unreachable."""
        if self.store is None:
            return []
        from repro.store.base import StoreError
        try:
            mans, deps = self._remote_manifests()
        except StoreError:
            return []
        out = []
        for s in mans:
            cur, seen = s, set()
            while cur in deps and cur in mans and cur not in seen:
                seen.add(cur)
                cur = deps[cur]
            if cur in mans and cur not in deps:   # bottoms out at a full
                out.append(s)                     # manifest, cycle-free
        return sorted(out)

    def latest(self) -> Optional[int]:
        """Newest COMPLETE, fully-landed step — local `.reft` families
        and manifest-complete remote families on equal footing; a family
        whose async persist is still in flight is never reported (its
        shards may all exist while a final fsync or manifest write is
        pending)."""
        steps = [s for s in set(self.complete_steps())
                 | set(self.remote_complete_steps())
                 if s not in self._inflight]
        return max(steps) if steps else None

    # --------------------------------------------------------- manifest
    def commit(self) -> dict:
        """Atomically publish the manifest and GC beyond keep-latest-k."""
        steps = self.complete_steps()
        kept = steps[-self.keep:] if self.keep else steps
        manifest = {"n_members": self.n, "complete_steps": kept}
        if self.store is not None:
            manifest["remote_steps"] = self.remote_complete_steps()
        tmp = os.path.join(self.dir, MANIFEST + ".tmp")
        try:
            with open(tmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.dir, MANIFEST))
        finally:
            try:
                os.unlink(tmp)             # no-op after a clean replace
            except FileNotFoundError:
                pass
        self._gc(set(kept))
        self._gc_remote()
        return manifest

    def read_manifest(self) -> Optional[dict]:
        try:
            with open(os.path.join(self.dir, MANIFEST)) as f:
                return json.load(f)
        except FileNotFoundError:
            return None

    def _gc(self, keep_steps: set) -> int:
        """Drop superseded complete steps AND torn (incomplete) families.

        Torn families used to survive whenever their step was >= the newest
        kept step, so every crashed partial checkpoint leaked forever; see
        `plan_gc` for the policy (a possibly in-flight newest torn family
        is spared)."""
        from repro.core.recovery import (
            delta_families, resolve_chain, restorable_steps,
        )
        removed = 0
        shards = scan_shards(self.dir)
        deltas = delta_families(self.dir)
        families = {s: None for s in set(shards) | set(deltas)}
        complete = set(restorable_steps(self.dir, self.n))
        full = {s: set(ns) for s, ns in shards.items()}
        deps: Dict[int, int] = {}
        for s in deltas:
            if s in shards:
                continue
            res = resolve_chain(self.dir, s, full, deltas)
            if res is not None:
                for st, base in res[1]:
                    deps[st] = base
        for s in plan_gc(families, complete, set(keep_steps),
                         spare_newest_torn=True, inflight=self._inflight,
                         deps=deps):
            for node in shards.get(s, ()):
                try:
                    os.remove(os.path.join(
                        self.dir, f"step-{s}-node-{node}.reft"))
                    removed += 1
                except FileNotFoundError:
                    pass
            for base, nodes in deltas.get(s, {}).items():
                for node in nodes:
                    try:
                        os.remove(os.path.join(
                            self.dir,
                            f"step-{s}-from-{base}-node-{node}.reftd"))
                        removed += 1
                    except FileNotFoundError:
                        pass
        return removed

    def _gc_remote(self) -> int:
        """Same keep-k policy over remote families: complete = manifest
        present; torn = shard/part objects with no manifest (a crashed
        upload's orphans).  Store errors skip the sweep — retention is
        best-effort, never a persist-path failure."""
        if self.store is None:
            return 0
        from repro.store.base import StoreError
        from repro.store.manifest import delete_family, list_step_prefixes
        try:
            complete = set(self.remote_complete_steps())
            mans, deps = self._remote_manifests()
            families = {s: None
                        for s in list_step_prefixes(self.store,
                                                    self.remote_prefix)}
            kept = sorted(complete)[-self.keep:] if self.keep \
                else sorted(complete)
            removed = 0
            for s in plan_gc(families, complete, set(kept),
                             spare_newest_torn=True,
                             inflight=self._inflight, deps=deps):
                removed += delete_family(self.store, self.remote_prefix, s)
            return removed
        except StoreError:
            return 0
