from repro.optim.adam import AdamConfig, adam_init, adam_update

__all__ = ["AdamConfig", "adam_init", "adam_update"]
