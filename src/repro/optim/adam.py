"""AdamW in pure JAX (optax is not available in this environment).

Moments are kept in fp32 regardless of param dtype — these are exactly the
"triple extra parameters" the paper's snapshots must protect (§6.1).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment storage dtype: "float32" (default, paper-faithful) or
    # "bfloat16" — halves optimizer-state memory (the knob that lets
    # kimi-k2-1t train on 512 chips, EXPERIMENTS §Dry-run finding)
    moments_dtype: str = "float32"


def adam_init(params, cfg: AdamConfig | None = None):
    # default built per call: a module-level AdamConfig() instance would
    # be shared by every caller (the PR 1 aliased-config bug class)
    cfg = cfg if cfg is not None else AdamConfig()
    dt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adam_update(cfg: AdamConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, mu, nu, p):
        mdt = mu.dtype                      # moment storage dtype
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * delta
        return new_p.astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, mu, nu, p)
           for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
