"""Serving demo: batched prefill + decode with a KV cache.

Runs the same serve_step the dry-run lowers for decode_32k/long_500k,
here on a reduced model with a batch of synthetic requests.

    PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M


def main():
    cfg = get_config("gemma3-4b").reduced()      # SWA + global interleave
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, prompt_len, gen_len, max_seq = 4, 16, 24, 64

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                 0, cfg.vocab_size)

    # prefill: consume the prompt once, then decode token by token
    cache = M.init_cache(cfg, B, max_seq)
    decode = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
    tok = prompts[:, :1]
    t0 = time.time()
    out_tokens = []
    for t in range(prompt_len + gen_len - 1):
        logits, cache = decode(params, cache, tok)
        if t + 1 < prompt_len:
            tok = prompts[:, t + 1:t + 2]        # teacher-forced prefill
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"served batch={B}: generated {gen.shape[1]} tokens/request "
          f"in {dt:.2f}s ({B * gen.shape[1] / dt:.1f} tok/s)")
    print("sample:", gen[0, :12].tolist())
    assert gen.shape == (B, gen_len)
    assert not bool(jnp.any(jnp.isnan(logits)))


if __name__ == "__main__":
    main()
