"""Multi-process failure drill: 4 real node processes, real SIGKILLs.

Demonstrates the paper's elastic workflow (Figure 2): healthy lockstep
training -> software failure (trainer dies, SMP survives) -> in-memory
resume -> node failure -> RAIM5 decode -> elastic replacement -> a
double-failure falling back to REFT-Ckpt.  The cluster is configured by
the same `CheckpointSpec` the facade uses, and every recovery goes through
the shared three-tier ladder.

    PYTHONPATH=src python examples/failure_recovery.py
"""
import numpy as np

from repro.api import CheckpointSpec
from repro.core.cluster import LocalCluster


def bitexact(a, b):
    import jax
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def main():
    spec = CheckpointSpec(backend="reft", ckpt_dir="/tmp/reft-drill",
                          sg_size=4, snapshot_every_steps=1,
                          bucket_bytes=1 << 20)
    c = LocalCluster(4, seed=1, nbytes=1 << 18, spec=spec)
    try:
        c.run_rounds(5)
        print("== software failure: SIGKILL trainer on node 1")
        c.kill_trainer(1)
        state, step, tier = c.recover()
        print(f"   recovered via {tier} @ step {step}, "
              f"bit-exact={bitexact(state, c.expected_state(step))}")
        c.restart_node(1, state)

        c.run_rounds(3)
        c.checkpoint()                       # REFT-Ckpt tier persists shards
        print("== node failure: SIGKILL trainer+SMP on node 2, wipe memory")
        c.kill_node(2)
        state, step, tier = c.recover()
        print(f"   recovered via {tier} @ step {step}, "
              f"bit-exact={bitexact(state, c.expected_state(step))}")
        c.restart_node(2, state)

        c.run_rounds(2)
        print("== double failure in one SG: nodes 0 and 3")
        c.kill_node(0)
        c.kill_node(3)
        state, step, tier = c.recover()
        print(f"   recovered via {tier} @ step {step}, "
              f"bit-exact={bitexact(state, c.expected_state(step))}")
    finally:
        c.close()


if __name__ == "__main__":
    main()
