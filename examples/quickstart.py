"""Quickstart: train a tiny model with REFT in-memory fault tolerance.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import ReftConfig, ReftGroup
from repro.data.pipeline import SyntheticDataset
from repro.train.steps import init_train_state, make_train_step


def main():
    cfg = get_config("qwen3-8b").reduced()        # 2-layer smoke variant
    shape = InputShape("demo", 64, 2, "train")
    state = init_train_state(cfg, 0).tree()
    ds = SyntheticDataset(cfg, shape)
    step_fn = jax.jit(make_train_step(cfg))

    # one sharding group of 4 simulated nodes, each with a real SMP process
    group = ReftGroup(4, state, ReftConfig(ckpt_dir="/tmp/reft-quickstart"))
    try:
        for _ in range(6):
            state, metrics = step_fn(state, next(ds))
            step = int(state["step"])
            group.snapshot(state, step, extra_meta=ds.state())
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"(snapshot clean @ {step})")

        # simulate losing a whole node: RAIM5 decodes its shard from parity
        group.inject_node_failure(2)
        recovered, rstep, extra, tier = group.recover()
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(recovered),
                                   jax.tree.leaves(state)))
        print(f"recovered via {tier} at step {rstep}; bit-exact: {same}")
        assert same and rstep == step
    finally:
        group.close()


if __name__ == "__main__":
    main()
