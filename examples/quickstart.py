"""Quickstart: train a tiny model behind the unified checkpointing facade.

Any registered backend drops in with one line — swap "reft" for
"sync_disk" / "async_disk" and the same loop runs against a disk baseline.

    PYTHONPATH=src python examples/quickstart.py [--backend reft]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import CheckpointSession, CheckpointSpec
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticDataset
from repro.train.steps import (init_train_state, make_train_step,
                               with_step_boundary)


def main(backend: str = "reft"):
    cfg = get_config("qwen3-8b").reduced()        # 2-layer smoke variant
    shape = InputShape("demo", 64, 2, "train")
    state = init_train_state(cfg, 0).tree()
    ds = SyntheticDataset(cfg, shape)
    # this loop never calls sess.after_step, so the wrapper is what ticks
    # the HASC gate: in-flight snapshot pipelines yield at step boundaries
    step_fn = with_step_boundary(jax.jit(make_train_step(cfg)))

    # one sharding group of 4 simulated nodes (for reft: one real SMP
    # process per member)
    spec = CheckpointSpec(backend=backend, ckpt_dir="/tmp/reft-quickstart",
                          sg_size=4, resume=False)
    with CheckpointSession(spec, state) as sess:
        for _ in range(6):
            state, metrics = step_fn(state, next(ds))
            step = int(state["step"])
            sess.snapshot(state, step, extra_meta=ds.state(), wait=True)
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"(snapshot clean @ {step})")

        # simulate losing a whole node: the reft backend RAIM5-decodes its
        # shard from parity; disk backends reload the last complete save
        sess.inject("node", node=2)
        res = sess.restore()
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(res.state),
                                   jax.tree.leaves(state)))
        print(f"recovered via {res.tier} at step {res.step}; "
              f"bit-exact: {same}")
        assert same and res.step == step
    print("events:", [f"{e.kind}@{e.step}" for e in sess.events][-6:])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reft",
                    choices=["reft", "sync_disk", "async_disk"])
    main(ap.parse_args().backend)
