"""Dry-run demo: lower + compile a production-mesh train step and print
its roofline terms — the exact flow `launch/dryrun.py --all` runs for
every (architecture x input shape).

Uses 64 placeholder devices (8x8 mesh) to keep the demo snappy; the real
campaigns use 512.  MUST set XLA_FLAGS before importing jax.

    PYTHONPATH=src python examples/dryrun_demo.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"

import jax

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, InputShape
from repro.launch import dryrun as DR


def main():
    # a small shape so the demo compiles in seconds
    INPUT_SHAPES["demo_1k"] = InputShape("demo_1k", 1024, 32, "train")
    mesh = jax.make_mesh((8, 8), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_config("qwen3-8b")
    lowered, meta = DR.build_lowered("qwen3-8b", "demo_1k", mesh,
                                     unroll=False, cfg=cfg)
    compiled = lowered.compile()
    rec = DR.analyse(lowered, compiled, meta, cfg)
    print(f"arch={rec['arch']} shape={rec['shape']} mesh={rec['mesh']}")
    print(f"  HLO FLOPs/chip       {rec['hlo_flops_per_chip']:.3e}")
    print(f"  HLO bytes/chip       {rec['hlo_bytes_per_chip']:.3e}")
    print(f"  collective B/chip    {rec['collective_bytes']['total']:.3e}")
    print(f"  roofline terms (s)   compute={rec['t_compute_s']:.4f} "
          f"memory={rec['t_memory_s']:.4f} "
          f"collective={rec['t_collective_s']:.4f}")
    print(f"  dominant term        {rec['dominant']}")
    print(f"  state bytes/chip     "
          f"{rec['memory'].get('argument_bytes', 0)/2**30:.2f} GiB")
    assert rec["hlo_flops_per_chip"] > 0
    print("dry-run demo OK")


if __name__ == "__main__":
    main()
